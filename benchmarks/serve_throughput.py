"""Beyond-paper: contiguous vs UniMem-paged serving, measured end-to-end.

Runs the SAME request stream through both engine layouts and reports
tokens/s plus peak KV bytes — for a dense batch/seq sweep AND a
`--family` sweep over the whole paged-native model zoo (dense, moe,
hybrid, vlm; vlm requests carry patch embeddings, hybrid pages its
attention KV share while conv/SSM state stays contiguous per slot).
The paper's claim, serving-shaped: a single pooled page arena makes KV
memory proportional to tokens in flight while the contiguous layout
pins `max_batch * max_seq` regardless of load.  PASS requires (a) both
layouts emit identical greedy tokens on every row and (b) paged peak KV
bytes never exceed contiguous (CPU wall-clock is reported, not judged —
this container is not the serving hardware).

`--impl flash_pallas --ppb N` reruns the paged side through the FUSED
single-pass kernels (`kernels/paged_attention` + `kernels/paged_prefill`,
interpret mode off-TPU) with N pages per grid cell — the CI smoke for
the TPU-tiled hot path.  `--shards N` serves the paged side from the
NEAR-MEMORY SHARDED arena (`serve/sharded/`) on an N-device "mem" mesh
(CI forces host devices via XLA_FLAGS) — same token-parity and KV
gates, plus per-shard page high-water in the report.  `--sampling` adds
the IN-STEP sampling sweep: the same dense stream rerun with
per-request temperature + top-p + seeds (serve/sampling.py lowers them
into the jitted step), gated on seed-replay determinism, reporting
greedy vs sampled tokens/s so the sampling overhead is tracked.
`--kv-dtype int8|fp8` stores the paged side QUANTIZED (per-page scales,
in-kernel dequant); `--quant` adds the capacity sweep gating the int8
arena at <= 0.55x bf16 page bytes with identical greedy tokens, and
`--host-tier` adds the forced-watermark spill smoke (DRAM cold bank
behind the pool; gated on nonzero spill+restore traffic and token
identity with an all-HBM run).  `--prefix-trace` adds the SHARED SYSTEM
PROMPT trace: sequential requests with a common 96-token prefix served
through the persistent prefix store, gated on nonzero cross-request
hits, fewer prompt tokens computed, steady-state TTFT below the cold
run, and identical greedy tokens.  `--speculate K [--draft SPEC]` adds
the SPECULATIVE DECODE sweep: K-token draft windows verified in one
batched call vs plain one-token decode, gated on byte-identical streams
(greedy AND sampled — the determinism contract makes speculation a pure
perf knob) at tokens/s ratio > 1, reporting accept rate and draft/verify
token traffic.  `--json PATH` additionally writes a machine-readable
`BENCH_serve.json` (`"schema": 6` — tokens/s, peak KV bytes per tier,
kv_dtype, shard topology + per-shard KV high-water, spill/prefetch
counts, the sampling-mode sweep, prefix hit rate + TTFT, the
speculative-decode sweep, and the compiled-HLO attention traffic of the
jitted steps before/after the kernel fusion).

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--family dense,moe,hybrid,vlm] [--impl flash_pallas] [--ppb 2] \
        [--shards 8] [--sampling] [--kv-dtype int8] [--quant] \
        [--host-tier] [--prefix-trace] [--speculate 4] \
        [--json BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import numpy as np
import jax

from repro.models.config import ModelConfig
from repro.models import registry
from repro.serve import ServingEngine, Request, SamplingParams, TokenEvent

# machine-readable result schema, versioned so trajectory tooling can
# evolve: 2 added shard topology + per-shard KV high-water; 3 added the
# --sampling sweep (mode, greedy vs sampled tokens/s, determinism gate);
# 4 added kv_dtype + the quantized-arena sweep (int8 page bytes <= 0.55x
# bf16 at identical greedy tokens) and the host-tier spill smoke (HBM +
# host arena bytes, spill/prefetch/restore traffic); 5 added the
# --prefix-trace shared-system-prompt sweep (prefix hit rate, prompt
# pages prefilled vs reused, steady-state TTFT cached vs cold); 6 added
# the --speculate sweep (accept rate, draft/verify token counts,
# speculative vs plain tokens/s, gated on byte-identical streams —
# greedy AND sampled — at ratio > 1)
SCHEMA = 6

CFG = ModelConfig(
    name="bench-dense", family="dense", num_layers=2, d_model=64,
    vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    attn_chunk=32, max_seq=256)

# quantized-arena sweep point: head_dim 64 so the int8 payload
# amortizes the f32 per-token-per-head scale column — the page-bytes
# ratio is (hd + 4) / (2 hd) = 0.53 at hd=64 (0.625 at hd=16, which
# would never clear the 0.55 gate: scales are a per-HEAD overhead,
# paying off only at real head widths)
QUANT_CFG = ModelConfig(
    name="bench-quant", family="dense", num_layers=2, d_model=128,
    vocab_size=128, num_heads=2, num_kv_heads=1, head_dim=64, d_ff=128,
    attn_chunk=32, max_seq=256)

FAMILY_CFGS = {
    "dense": CFG,
    "moe": ModelConfig(
        name="bench-moe", family="moe", num_layers=2, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16,
        num_experts=4, experts_per_token=2, moe_d_ff=32,
        num_shared_experts=1, attn_chunk=32, max_seq=256),
    "hybrid": ModelConfig(
        name="bench-hybrid", family="hybrid", num_layers=4, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=128,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=16, shared_attn_period=2,
        num_shared_blocks=2, attn_chunk=32, max_seq=256),
    "vlm": ModelConfig(
        name="bench-vlm", family="vlm", num_layers=2, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        frontend="patch", frontend_dim=32, num_patches=8,
        attn_chunk=32, max_seq=256),
}

# dense-only scaling sweep: (max_batch, max_seq, requests, prompt_hi, max_new)
SWEEP = [
    (2, 64, 6, 20, 6),
    (4, 128, 8, 48, 8),
    (4, 256, 8, 96, 8),
]

# family sweep point (tiny: CI smoke runs this on CPU)
FAM_POINT = dict(mb=2, ms=64, n=4, phi=24, mnew=5)


def _stream(rng, cfg, n, prompt_hi, max_new):
    reqs = []
    for i in range(n):
        pe = (rng.standard_normal((cfg.num_patches, cfg.frontend_dim))
              .astype(np.float32) if cfg.frontend == "patch" else None)
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, prompt_hi))
                                ).astype(np.int32),
            max_new_tokens=max_new, patch_embeds=pe))
    return reqs


def _run(cfg, params, layout, reqs, mb, ms, mesh=None, **eng_kw):
    eng = ServingEngine(cfg, params, max_batch=mb, max_seq=ms,
                        page_size=16, layout=layout,
                        mesh=mesh if layout == "paged" else None, **eng_kw)
    for r in reqs:
        eng.submit(Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           patch_embeds=r.patch_embeds))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    toks = {r.uid: tuple(r.tokens) for r in results}
    out = dict(tok_s=sum(len(t) for t in toks.values()) / dt,
               peak_kv_bytes=eng.peak_kv_bytes(), tokens=toks,
               shared=eng.pool.stats().shared_pages,
               prefill_shapes=len(eng.prefill_shapes))
    if eng.mesh is not None:
        out["per_shard_peak_pages"] = [
            s["peak_allocated_pages"] for s in eng.pool.shard_stats()]
        out["per_shard_kv_bytes"] = eng.arena.shard_kv_bytes()
    if eng.host_tier is not None:
        out["host_tier"] = eng.stats()["host_tier"]
    return out


def _row(cfg, params, reqs, mb, ms, oracle_cfg=None, mesh=None):
    """paged side runs `cfg` (possibly --impl/--ppb/--shards overridden);
    the contiguous reference stays on `oracle_cfg` (the default XLA
    impl, single device), so the parity gate is
    fused-kernels/sharded-arena-vs-oracle, never fused-vs-fused."""
    contig = _run(oracle_cfg or cfg, params, "contiguous", reqs, mb, ms)
    paged = _run(cfg, params, "paged", reqs, mb, ms, mesh=mesh)
    same = contig["tokens"] == paged["tokens"]
    row = dict(
        family=cfg.family, batch=mb, max_seq=ms, requests=len(reqs),
        contig_tok_s=contig["tok_s"], paged_tok_s=paged["tok_s"],
        contig_kv_mb=contig["peak_kv_bytes"] / 1e6,
        paged_kv_mb=paged["peak_kv_bytes"] / 1e6,
        kv_ratio=paged["peak_kv_bytes"] / contig["peak_kv_bytes"],
        prefill_shapes=paged["prefill_shapes"],
        tokens_match=same,
        ok=same and paged["peak_kv_bytes"] <= contig["peak_kv_bytes"],
    )
    for k in ("per_shard_peak_pages", "per_shard_kv_bytes"):
        if k in paged:
            row[k] = paged[k]
    return row


def _attention_hlo_stats(cfg) -> dict:
    """Compiled-HLO attention traffic of the jitted paged steps, before
    (XLA oracle formulation: per-layer gathered KV copies) vs after
    (fused Pallas kernels: block-table walk in VMEM).  Bytes come from
    `launch/hlo_analysis` shape accounting over the ACTUAL serving
    closures; the gathered/partials keys are the bulk buffers the
    fusion exists to kill."""
    from repro.launch.hlo_analysis import summarize
    from repro.serve.serve_step import (
        HLO_PROBE_GEOM, bulk_attn_shapes, lowered_paged_hlo)

    bulk_shapes = bulk_attn_shapes(cfg, **HLO_PROBE_GEOM)
    params = registry.get_family(cfg).init(jax.random.key(0), cfg)
    out = {"bulk_attn_shapes": bulk_shapes,
           "backend": jax.default_backend(),
           # off-TPU the flash_pallas steps lower through the Pallas
           # INTERPRETER, whose emulation buffers inflate whole-step
           # totals ~10x — only the bulk_attn_bytes keys are
           # layout-meaningful there; hbm_bytes are backend proxies
           "hbm_bytes_note": ("whole-step totals are backend-lowering "
                              "proxies; off-TPU only bulk_attn_bytes_* "
                              "compare before/after meaningfully")}
    for tag, c in (("before", cfg),
                   ("after", cfg.replace(attention_impl="flash_pallas"))):
        for which in ("decode", "prefill"):
            s = summarize(lowered_paged_hlo(c, which, params=params,
                                            **HLO_PROBE_GEOM))
            bulk = sum(s.bytes_by_shape.get(k, 0.0) for k in bulk_shapes)
            out[f"{which}_bulk_attn_bytes_{tag}"] = bulk
            out[f"{which}_hbm_bytes_{tag}"] = s.hbm_bytes
    return out


def _sampling_sweep(cfg, params, mesh=None) -> dict:
    """Greedy vs per-request-sampled serving on the SAME stream.

    Every request carries its own SamplingParams (temperature ramp,
    top-p nucleus, top-k on odd uids, per-request seed) lowered into
    the jitted step.  PASS requires (a) a seed replay reproduces the
    sampled tokens byte-for-byte (counter-derived randomness — the
    determinism the API guarantees) and (b) the sampled run actually
    diverges from greedy somewhere (the knobs reach the kernel).
    Reported tokens/s tracks the in-step sampling overhead."""
    mb, ms, mnew = 4, 128, 8
    rng = np.random.default_rng(12345)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48)))
               .astype(np.int32) for _ in range(8)]

    def params_for(uid):
        return SamplingParams(temperature=0.7 + 0.05 * uid,
                              top_k=8 if uid % 2 else 0, top_p=0.9,
                              seed=uid, max_new_tokens=mnew)

    def serve(sampled):
        eng = ServingEngine(cfg, params, max_batch=mb, max_seq=ms,
                            page_size=16, mesh=mesh)
        for uid, p in enumerate(prompts):
            eng.submit(Request(
                uid=uid, prompt=p.copy(),
                sampling=params_for(uid) if sampled
                else SamplingParams(max_new_tokens=mnew)))
        t0 = time.perf_counter()
        toks = {r.uid: tuple(r.tokens) for r in eng.run()}
        dt = time.perf_counter() - t0
        return toks, sum(len(t) for t in toks.values()) / dt

    greedy, greedy_tok_s = serve(sampled=False)
    sampled, sampled_tok_s = serve(sampled=True)
    replay, _ = serve(sampled=True)
    deterministic = sampled == replay
    diverged = sampled != greedy
    return dict(mode="per-request temperature + top-p (+ top-k odd uids)",
                requests=len(prompts),
                greedy_tok_s=greedy_tok_s, sampled_tok_s=sampled_tok_s,
                sampled_over_greedy=sampled_tok_s / greedy_tok_s,
                deterministic=deterministic, diverged_from_greedy=diverged,
                ok=deterministic and diverged)


def _quant_sweep(mesh=None, impl=None, ppb=1) -> dict:
    """int8 page arena vs bf16 on the SAME greedy stream.

    The capacity claim of the quantized page mode, measured end to end:
    at head_dim 64 the int8 payload + f32 scale column must hold the
    paged KV high-water to <= 0.55x the bf16 arena's, AND the greedy
    tokens must stay identical (quantize-on-write + in-kernel dequant
    never flips an argmax on this workload — the numerics smoke)."""
    mb, ms, n, phi, mnew = 4, 128, 8, 48, 8
    base = QUANT_CFG
    if impl:
        base = base.replace(attention_impl=impl)
    base = base.replace(attn_pages_per_block=ppb)
    rng = np.random.default_rng(777)
    reqs = _stream(rng, base, n, phi, mnew)
    runs = {}
    for tag in ("bf16", "int8"):
        # pin the baseline to bf16 STORAGE explicitly — the default
        # arena stores the compute dtype (f32 on CPU), which would
        # overstate the int8 win
        cfg = base.replace(kv_dtype=tag)
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        runs[tag] = _run(cfg, params, "paged", reqs, mb, ms, mesh=mesh)
    ratio = runs["int8"]["peak_kv_bytes"] / runs["bf16"]["peak_kv_bytes"]
    same = runs["bf16"]["tokens"] == runs["int8"]["tokens"]
    return dict(head_dim=base.head_dim, requests=n,
                bf16_kv_mb=runs["bf16"]["peak_kv_bytes"] / 1e6,
                int8_kv_mb=runs["int8"]["peak_kv_bytes"] / 1e6,
                bytes_ratio=ratio,
                bf16_tok_s=runs["bf16"]["tok_s"],
                int8_tok_s=runs["int8"]["tok_s"],
                tokens_match=same,
                ok=same and ratio <= 0.55)


def _tier_sweep(mesh=None) -> dict:
    """Forced-watermark host-tier smoke: a pool deliberately sized PAST
    by the workload, with the DRAM cold tier behind it.

    The shedder preempts under the high watermark, preempted slots
    SPILL to host, readmissions RESTORE (with async prefetch) — and the
    stream must finish with tokens identical to an all-HBM run of the
    same requests.  PASS requires nonzero spill AND restore traffic plus
    token identity; the report carries both arenas' bytes (HBM page
    high-water, host-tier peak) so capacity-vs-traffic is visible."""
    mb, ms, n, phi, mnew = 4, 128, 8, 48, 10
    rng = np.random.default_rng(4242)
    reqs = _stream(rng, CFG, n, phi, mnew)
    params = registry.get_family(CFG).init(jax.random.key(0), CFG)
    base = _run(CFG, params, "paged", reqs, mb, ms, mesh=mesh,
                pool_pages=64)
    # limit = 0.5 * 16 = 8 pages vs ~4 pages per active sequence: the
    # shedder MUST preempt, so the tier MUST see spill traffic
    tiered = _run(CFG, params, "paged", reqs, mb, ms, mesh=mesh,
                  pool_pages=16, high_watermark=0.5, host_tier_pages=64)
    ht = tiered["host_tier"]
    same = base["tokens"] == tiered["tokens"]
    spilled = ht["spills"] > 0 and ht["restores"] > 0
    return dict(requests=n, pool_pages=16, high_watermark=0.5,
                host_tier_pages=64,
                all_hbm_kv_mb=base["peak_kv_bytes"] / 1e6,
                tiered_hbm_kv_mb=tiered["peak_kv_bytes"] / 1e6,
                host_tier_peak_mb=ht["peak_bytes"] / 1e6,
                spills=ht["spills"], spilled_pages=ht["spilled_pages"],
                prefetches=ht["prefetches"], restores=ht["restores"],
                restored_pages=ht["restored_pages"],
                evictions=ht["evictions"],
                all_hbm_tok_s=base["tok_s"], tiered_tok_s=tiered["tok_s"],
                tokens_match=same,
                ok=same and spilled)


def _prefix_sweep(mesh=None) -> dict:
    """--prefix-trace: N requests sharing one SYSTEM PROMPT, served
    strictly sequentially — every donor fully retires before the next
    request arrives, so any page reuse crosses request lifetimes through
    the persistent prefix store (serve/prefix_store.py), never through a
    live co-resident donor.

    The cached run must (a) actually hit — nonzero cross-request store
    hits and strictly fewer prompt tokens computed than the cold run,
    (b) stay byte-identical to the cold run on every request's greedy
    tokens, and (c) beat the cold run on steady-state TTFT (median over
    requests >= 2, past jit warmup): with a 96-token system prompt and
    16-token prefill chunks, a hit replaces six prefill dispatches per
    request with page adoption."""
    n, sys_len, tail_len, mnew = 8, 96, 8, 6
    rng = np.random.default_rng(909)
    system = rng.integers(0, CFG.vocab_size, sys_len).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size, tail_len).astype(np.int32)])
        for _ in range(n)]
    params = registry.get_family(CFG).init(jax.random.key(0), CFG)

    def serve(cached):
        eng = ServingEngine(CFG, params, max_batch=2, max_seq=256,
                            page_size=16, prefill_chunk=16, pool_pages=64,
                            mesh=mesh, prefix_cache=cached)
        ttft = {}
        for uid, p in enumerate(prompts):
            t0 = time.perf_counter()
            eng.submit(Request(uid=uid, prompt=p.copy(),
                               max_new_tokens=mnew))
            for ev in eng.stream():     # runs this request to retirement
                if (isinstance(ev, TokenEvent) and ev.uid == uid
                        and ev.index == 0):
                    ttft[uid] = time.perf_counter() - t0
        toks = {r.uid: tuple(r.tokens) for r in eng.results}
        steady = float(np.median([ttft[u] for u in range(2, n)]))
        return dict(tokens=toks, ttft=ttft, steady_ttft_s=steady,
                    prefill_tokens=eng.prefill_tokens,
                    store=eng.prefix_store.stats())

    cold = serve(cached=False)
    warm = serve(cached=True)
    st = warm["store"]
    hit_rate = st["reused_pages"] / max(1, st["reused_pages"]
                                        + st["registered_pages"])
    same = cold["tokens"] == warm["tokens"]
    faster = warm["steady_ttft_s"] < cold["steady_ttft_s"]
    return dict(requests=n, system_tokens=sys_len, page_size=16,
                prefill_chunk=16,
                cross_request_hits=st["cross_request_hits"],
                pages_reused=st["reused_pages"],
                pages_prefilled=st["registered_pages"],
                prefix_hit_rate=hit_rate,
                prefill_tokens_cached=warm["prefill_tokens"],
                prefill_tokens_cold=cold["prefill_tokens"],
                steady_ttft_cached_s=warm["steady_ttft_s"],
                steady_ttft_cold_s=cold["steady_ttft_s"],
                ttft_speedup=cold["steady_ttft_s"] / warm["steady_ttft_s"],
                tokens_match=same,
                ok=(same and faster and st["cross_request_hits"] > 0
                    and warm["prefill_tokens"] < cold["prefill_tokens"]))


def _high_agreement(params):
    """Zero the residual output projections of every layer past the
    first, making layers 1..L-1 exact identities on the residual
    stream.  A `self:1` draft (layer 0 + the shared final norm/head)
    then computes logits IDENTICAL to the target's, so the accept rate
    is exactly 1.0 — the trace measures the speculation MACHINERY's
    ceiling (how much one fused propose+verify dispatch saves over k+1
    sequential decode dispatches) rather than a random-init draft's
    agreement, which is ~chance and tells you nothing about the
    machinery.  Real deployments sit between the two; the JSON reports
    `accept_rate` so the trace's position on that axis is explicit."""
    out = {**params, "layers": dict(params["layers"])}
    for mod in ("attn", "mlp"):
        wo = np.asarray(params["layers"][mod]["wo"]).copy()
        wo[1:] = 0.0
        out["layers"][mod] = {**params["layers"][mod], "wo": wo}
    return out


def _speculate_sweep(k: int, draft: str, mesh=None) -> dict:
    """--speculate K: draft-propose / batched-verify decode vs plain
    one-token decode on the SAME decode-heavy stream.

    The determinism contract makes this a pure perf knob: acceptance is
    an exact match against the target's own counter-keyed draw, so the
    speculative stream must be BYTE-IDENTICAL to plain decode — greedy
    and sampled — and the gate enforces exactly that, plus a tokens/s
    ratio > 1 (each accepted window folds up to k+1 sequential decode
    dispatches into one propose + one verify call).  Reported: accept
    rate, draft/verify token traffic, and the speculative:plain ratio.

    The target is CFG deepened to 8 layers with `_high_agreement`
    params (accept rate 1.0, draft = 1/8 of the target): the regime
    where speculation pays — a cheap draft that tracks its target —
    exercised end-to-end through real paging, forks and retirement."""
    mb, ms, n, phi, mnew = 4, 256, 8, 16, 64
    cfg = dataclasses.replace(CFG, num_layers=8)
    rng = np.random.default_rng(31337)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, phi)))
               .astype(np.int32) for _ in range(n)]
    params = _high_agreement(
        registry.get_family(cfg).init(jax.random.key(0), cfg))

    def serve(spec_k, sampled):
        # ONE engine per mode, warmup batch first: every engine builds
        # fresh jit closures, so a cold run times XLA compilation, not
        # decode — the timed batch reuses the warm engine (requests are
        # independent streams; warmup never changes the timed tokens)
        eng = ServingEngine(cfg, params, max_batch=mb, max_seq=ms,
                            page_size=16, mesh=mesh, speculate_k=spec_k,
                            draft=draft if spec_k else None)

        def batch(base_uid):
            for uid, p in enumerate(prompts):
                eng.submit(Request(uid=base_uid + uid, prompt=p.copy(),
                                   sampling=SamplingParams(
                                       temperature=0.7 if sampled and uid % 2
                                       else 0.0, seed=uid,
                                       max_new_tokens=mnew)))
            t0 = time.perf_counter()
            results = eng.run()                  # accumulates across batches
            dt = time.perf_counter() - t0
            return {r.uid - base_uid: tuple(r.tokens) for r in results
                    if r.uid >= base_uid}, dt

        batch(0)                                 # warmup: compiles
        toks, dt = batch(1000)
        return (toks, sum(len(t) for t in toks.values()) / dt,
                eng.stats().get("speculative"))

    plain, plain_tok_s, _ = serve(0, sampled=False)
    spec, spec_tok_s, st = serve(k, sampled=False)
    plain_s, _, _ = serve(0, sampled=True)
    spec_s, _, _ = serve(k, sampled=True)
    ratio = spec_tok_s / plain_tok_s
    same = plain == spec
    same_sampled = plain_s == spec_s
    return dict(k=k, draft=draft, requests=n, max_new_tokens=mnew,
                plain_tok_s=plain_tok_s, speculative_tok_s=spec_tok_s,
                speedup=ratio,
                accept_rate=st["accept_rate"],
                windows=st["windows"], verify_calls=st["verify_calls"],
                draft_tokens=st["draft_tokens"],
                accepted_tokens=st["accepted_tokens"],
                emitted_tokens=st["emitted_tokens"],
                tokens_match=same, tokens_match_sampled=same_sampled,
                # the ratio gate is single-device only: the fused
                # propose+verify dispatch is a single-arena construct,
                # so the mesh run is a byte-identity smoke for the
                # shard_map verify path, not a perf claim
                ok=same and same_sampled
                and (mesh is not None or ratio > 1.0))


def run(families=None, impl=None, ppb=1, attn_hlo=False,
        shards: int = 1, sampling: bool = False, kv_dtype: str | None = None,
        quant: bool = False, host_tier: bool = False,
        prefix_trace: bool = False, speculate: int = 0,
        draft: str = "self:1") -> dict:
    families = families or list(FAMILY_CFGS)
    mesh = None
    if shards > 1:
        from repro.launch.mesh import make_mem_mesh
        if jax.device_count() < shards:
            raise SystemExit(
                f"--shards {shards} needs {shards} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={shards})")
        mesh = make_mem_mesh(shards)

    def cfg_of(fam):
        cfg = FAMILY_CFGS[fam]
        if impl:
            cfg = cfg.replace(attention_impl=impl)
        if kv_dtype:
            # paged side only — the contiguous oracle keeps the default
            # cache dtype, so a quantized run is gated quant-vs-oracle
            cfg = cfg.replace(kv_dtype=kv_dtype)
        return cfg.replace(attn_pages_per_block=ppb)

    rows, ok = [], True
    # dense batch/seq scaling sweep (covers the dense family point too)
    if "dense" in families:
        cfg = cfg_of("dense")
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        for mb, ms, n, phi, mnew in SWEEP:
            rng = np.random.default_rng(hash((mb, ms)) % 2**32)
            r = _row(cfg, params, _stream(rng, cfg, n, phi, mnew), mb, ms,
                     oracle_cfg=FAMILY_CFGS["dense"], mesh=mesh)
            ok &= r["ok"]
            rows.append(r)
    # family sweep: the rest of the zoo paged-native at one tiny point
    for fam in families:
        if fam == "dense":
            continue
        cfg = cfg_of(fam)
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        # str hash() is salted per process — seed deterministically so
        # the CI smoke workload is reproducible run to run
        rng = np.random.default_rng(1000 + sum(map(ord, fam)))
        p = FAM_POINT
        r = _row(cfg, params, _stream(rng, cfg, p["n"], p["phi"], p["mnew"]),
                 p["mb"], p["ms"], oracle_cfg=FAMILY_CFGS[fam], mesh=mesh)
        ok &= r["ok"]
        rows.append(r)
    result = {"name": "serve_throughput", "schema": SCHEMA, "ok": ok,
              "rows": rows,
              "attention_impl": impl or CFG.attention_impl,
              "pages_per_block": ppb,
              "kv_dtype": kv_dtype or "bf16",
              "shard_topology": {"shards": shards,
                                 "mesh_axis": "mem" if mesh is not None
                                 else None,
                                 "devices": jax.device_count(),
                                 "backend": jax.default_backend()}}
    if quant:
        result["quant"] = _quant_sweep(mesh=mesh, impl=impl, ppb=ppb)
        ok = ok and result["quant"]["ok"]
        result["ok"] = ok
    if host_tier:
        result["host_tier"] = _tier_sweep(mesh=mesh)
        ok = ok and result["host_tier"]["ok"]
        result["ok"] = ok
    if prefix_trace:
        result["prefix"] = _prefix_sweep(mesh=mesh)
        ok = ok and result["prefix"]["ok"]
        result["ok"] = ok
    if sampling:
        cfg = cfg_of("dense")
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        result["sampling"] = _sampling_sweep(cfg, params, mesh=mesh)
        result["ok"] = ok = ok and result["sampling"]["ok"]
    if speculate > 0:
        result["speculative"] = _speculate_sweep(speculate, draft, mesh=mesh)
        result["ok"] = ok = ok and result["speculative"]["ok"]
    if attn_hlo:
        result["attention_hlo"] = _attention_hlo_stats(FAMILY_CFGS["dense"])
        # the fused steps must ship ZERO bulk attention bytes
        h = result["attention_hlo"]
        result["ok"] = ok = (ok and h["decode_bulk_attn_bytes_after"] == 0
                             and h["prefill_bulk_attn_bytes_after"] == 0
                             and h["decode_bulk_attn_bytes_before"] > 0
                             and h["prefill_bulk_attn_bytes_before"] > 0)
    return result


def pretty(result: dict):
    print("== Serving: contiguous slots vs UniMem paged arena "
          "(--family sweep: dense,moe,hybrid,vlm) ==")
    topo = result["shard_topology"]
    print(f"   attention_impl={result['attention_impl']} "
          f"pages_per_block={result['pages_per_block']} "
          f"kv_dtype={result['kv_dtype']} "
          f"shards={topo['shards']} ({topo['devices']} "
          f"{topo['backend']} devices)")
    print(f"{'family':>8}{'batch':>6}{'max_seq':>8}{'reqs':>6}"
          f"{'contig tok/s':>14}{'paged tok/s':>13}{'contig KV MB':>14}"
          f"{'paged KV MB':>13}{'KV ratio':>10}  tokens")
    for r in result["rows"]:
        shard = ""
        if "per_shard_peak_pages" in r:
            shard = f"  shard peaks {r['per_shard_peak_pages']}"
        print(f"{r['family']:>8}{r['batch']:>6}{r['max_seq']:>8}"
              f"{r['requests']:>6}"
              f"{r['contig_tok_s']:>14.1f}{r['paged_tok_s']:>13.1f}"
              f"{r['contig_kv_mb']:>14.3f}{r['paged_kv_mb']:>13.3f}"
              f"{r['kv_ratio']:>10.2f}  "
              f"{'==' if r['tokens_match'] else 'DIFFER'}{shard}")
    q = result.get("quant")
    if q:
        print(f"   quantized arena (head_dim {q['head_dim']}): bf16 "
              f"{q['bf16_kv_mb']:.3f} MB -> int8 {q['int8_kv_mb']:.3f} MB "
              f"({q['bytes_ratio']:.3f}x, gate <= 0.55); tokens "
              f"{'==' if q['tokens_match'] else 'DIFFER'}")
    p = result.get("prefix")
    if p:
        print(f"   prefix cache ({p['requests']} sequential requests, "
              f"{p['system_tokens']}-token shared system prompt): "
              f"hit rate {p['prefix_hit_rate']:.2f} "
              f"({p['pages_reused']} pages reused / "
              f"{p['pages_prefilled']} prefilled, "
              f"{p['cross_request_hits']} cross-request hits); prompt "
              f"tokens computed {p['prefill_tokens_cached']} vs cold "
              f"{p['prefill_tokens_cold']}; steady TTFT "
              f"{p['steady_ttft_cached_s']*1e3:.1f} ms vs cold "
              f"{p['steady_ttft_cold_s']*1e3:.1f} ms "
              f"({p['ttft_speedup']:.2f}x); tokens "
              f"{'==' if p['tokens_match'] else 'DIFFER'}")
    t = result.get("host_tier")
    if t:
        print(f"   host tier (pool {t['pool_pages']} pages @ watermark "
              f"{t['high_watermark']}): HBM {t['tiered_hbm_kv_mb']:.3f} MB "
              f"(all-HBM run {t['all_hbm_kv_mb']:.3f} MB), host peak "
              f"{t['host_tier_peak_mb']:.3f} MB; {t['spills']} spills / "
              f"{t['prefetches']} prefetches / {t['restores']} restores; "
              f"tokens {'==' if t['tokens_match'] else 'DIFFER'}")
    sp = result.get("speculative")
    if sp:
        print(f"   speculative decode (k={sp['k']}, draft {sp['draft']}): "
              f"plain {sp['plain_tok_s']:.1f} tok/s -> speculative "
              f"{sp['speculative_tok_s']:.1f} tok/s ({sp['speedup']:.2f}x); "
              f"accept rate {sp['accept_rate']:.2f} "
              f"({sp['accepted_tokens']}/{sp['draft_tokens']} draft tokens, "
              f"{sp['verify_calls']} verify calls); tokens "
              f"{'==' if sp['tokens_match'] else 'DIFFER'} greedy, "
              f"{'==' if sp['tokens_match_sampled'] else 'DIFFER'} sampled")
    s = result.get("sampling")
    if s:
        print(f"   in-step sampling [{s['mode']}]: greedy "
              f"{s['greedy_tok_s']:.1f} tok/s -> sampled "
              f"{s['sampled_tok_s']:.1f} tok/s "
              f"({s['sampled_over_greedy']:.2f}x); seed replay "
              f"{'identical' if s['deterministic'] else 'DIVERGED'}")
    h = result.get("attention_hlo")
    if h:
        print("   jitted-step attention traffic (compiled HLO, dense): "
              f"decode bulk {h['decode_bulk_attn_bytes_before']/1e3:.0f}kB"
              f" -> {h['decode_bulk_attn_bytes_after']/1e3:.0f}kB, "
              f"prefill bulk {h['prefill_bulk_attn_bytes_before']/1e3:.0f}kB"
              f" -> {h['prefill_bulk_attn_bytes_after']/1e3:.0f}kB")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} "
          "(identical greedy tokens; paged KV high-water <= contiguous "
          "on every family)\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default=",".join(FAMILY_CFGS),
                    help="comma-separated subset of "
                         f"{','.join(FAMILY_CFGS)} to sweep")
    ap.add_argument("--impl", default=None,
                    choices=("dense", "flash_xla", "flash_pallas"),
                    help="attention_impl override (flash_pallas = fused "
                         "paged kernels, interpret mode off-TPU)")
    ap.add_argument("--ppb", type=int, default=1,
                    help="pages per paged-kernel grid cell "
                         "(attn_pages_per_block)")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve the paged side from the near-memory "
                         "SHARDED arena on an N-device 'mem' mesh "
                         "(needs N devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--sampling", action="store_true",
                    help="add the in-step sampling sweep (per-request "
                         "temperature + top-p + seeds on the dense "
                         "stream; gated on seed-replay determinism)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("bf16", "int8", "fp8"),
                    help="page-arena storage dtype for the paged side of "
                         "the main sweep (quantize-on-write + in-kernel "
                         "dequant; the contiguous oracle stays bf16)")
    ap.add_argument("--quant", action="store_true",
                    help="add the quantized-arena sweep: int8 vs bf16 "
                         "page bytes at head_dim 64, gated on ratio "
                         "<= 0.55 AND identical greedy tokens")
    ap.add_argument("--host-tier", action="store_true",
                    help="add the host-tier spill smoke: forced-"
                         "watermark pool with a DRAM cold bank, gated "
                         "on nonzero spill+restore traffic AND tokens "
                         "identical to an all-HBM run")
    ap.add_argument("--prefix-trace", action="store_true",
                    help="add the shared-system-prompt trace: N "
                         "sequential requests with one system prompt "
                         "through the persistent prefix store; gated on "
                         "nonzero cross-request hits, fewer prompt "
                         "tokens computed, steady-state TTFT below the "
                         "cold run, AND identical greedy tokens")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="add the speculative-decode sweep: K-token "
                         "draft windows + one-call batched verify vs "
                         "plain decode, gated on BYTE-IDENTICAL streams "
                         "(greedy and sampled) at tokens/s ratio > 1")
    ap.add_argument("--draft", default="self:1",
                    help="draft spec for --speculate: 'self:N' "
                         "(truncated-layer self-draft) or an ARCHES "
                         "name, optionally '@reduced' (default self:1)")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="write machine-readable results (schema 6: "
                         "tokens/s, peak KV bytes per tier, kv_dtype, "
                         "shard topology, spill/prefetch counts, "
                         "sampling-mode sweep, attention HBM bytes "
                         "before/after the kernel fusion) to PATH")
    args = ap.parse_args()
    fams = [f.strip() for f in args.family.split(",") if f.strip()]
    unknown = [f for f in fams if f not in FAMILY_CFGS]
    if unknown:
        raise SystemExit(f"unknown families {unknown}; "
                         f"choose from {list(FAMILY_CFGS)}")
    res = {"name": "serve_throughput", "schema": SCHEMA, "ok": False,
           "error": "run() raised before completing"}
    try:
        res = run(fams, impl=args.impl, ppb=args.ppb,
                  attn_hlo=bool(args.json), shards=args.shards,
                  sampling=args.sampling, kv_dtype=args.kv_dtype,
                  quant=args.quant, host_tier=args.host_tier,
                  prefix_trace=args.prefix_trace,
                  speculate=args.speculate, draft=args.draft)
        pretty(res)
    finally:
        # write even when run() raises: the (partial) record is exactly
        # what a failing CI run needs uploaded
        if args.json:
            with open(args.json, "w") as f:
                json.dump(res, f, indent=1, default=str)
            print(f"wrote {args.json}")
    sys.exit(0 if res["ok"] else 1)
