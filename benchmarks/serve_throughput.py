"""Beyond-paper: contiguous vs UniMem-paged serving, measured end-to-end.

Runs the SAME request stream through both engine layouts and reports
tokens/s plus peak KV bytes — for a dense batch/seq sweep AND a
`--family` sweep over the whole paged-native model zoo (dense, moe,
hybrid, vlm; vlm requests carry patch embeddings, hybrid pages its
attention KV share while conv/SSM state stays contiguous per slot).
The paper's claim, serving-shaped: a single pooled page arena makes KV
memory proportional to tokens in flight while the contiguous layout
pins `max_batch * max_seq` regardless of load.  PASS requires (a) both
layouts emit identical greedy tokens on every row and (b) paged peak KV
bytes never exceed contiguous (CPU wall-clock is reported, not judged —
this container is not the serving hardware).

`--impl flash_pallas --ppb N` reruns the paged side through the FUSED
single-pass kernels (`kernels/paged_attention` + `kernels/paged_prefill`,
interpret mode off-TPU) with N pages per grid cell — the CI smoke for
the TPU-tiled hot path.  `--shards N` serves the paged side from the
NEAR-MEMORY SHARDED arena (`serve/sharded/`) on an N-device "mem" mesh
(CI forces host devices via XLA_FLAGS) — same token-parity and KV
gates, plus per-shard page high-water in the report.  `--sampling` adds
the IN-STEP sampling sweep: the same dense stream rerun with
per-request temperature + top-p + seeds (serve/sampling.py lowers them
into the jitted step), gated on seed-replay determinism, reporting
greedy vs sampled tokens/s so the sampling overhead is tracked.
`--json PATH` additionally writes a machine-readable `BENCH_serve.json`
(`"schema": 3` — tokens/s, peak KV bytes, shard topology + per-shard
KV high-water, the sampling-mode sweep, and the compiled-HLO attention
traffic of the jitted steps before/after the kernel fusion: the oracle
formulation's gathered-KV/partials bytes vs the fused kernels' zero).

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--family dense,moe,hybrid,vlm] [--impl flash_pallas] [--ppb 2] \
        [--shards 8] [--sampling] [--json BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax

from repro.models.config import ModelConfig
from repro.models import registry
from repro.serve import ServingEngine, Request, SamplingParams

# machine-readable result schema, versioned so trajectory tooling can
# evolve: 2 added shard topology + per-shard KV high-water; 3 added the
# --sampling sweep (mode, greedy vs sampled tokens/s, determinism gate)
SCHEMA = 3

CFG = ModelConfig(
    name="bench-dense", family="dense", num_layers=2, d_model=64,
    vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    attn_chunk=32, max_seq=256)

FAMILY_CFGS = {
    "dense": CFG,
    "moe": ModelConfig(
        name="bench-moe", family="moe", num_layers=2, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16,
        num_experts=4, experts_per_token=2, moe_d_ff=32,
        num_shared_experts=1, attn_chunk=32, max_seq=256),
    "hybrid": ModelConfig(
        name="bench-hybrid", family="hybrid", num_layers=4, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=128,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=16, shared_attn_period=2,
        num_shared_blocks=2, attn_chunk=32, max_seq=256),
    "vlm": ModelConfig(
        name="bench-vlm", family="vlm", num_layers=2, d_model=64,
        vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        frontend="patch", frontend_dim=32, num_patches=8,
        attn_chunk=32, max_seq=256),
}

# dense-only scaling sweep: (max_batch, max_seq, requests, prompt_hi, max_new)
SWEEP = [
    (2, 64, 6, 20, 6),
    (4, 128, 8, 48, 8),
    (4, 256, 8, 96, 8),
]

# family sweep point (tiny: CI smoke runs this on CPU)
FAM_POINT = dict(mb=2, ms=64, n=4, phi=24, mnew=5)


def _stream(rng, cfg, n, prompt_hi, max_new):
    reqs = []
    for i in range(n):
        pe = (rng.standard_normal((cfg.num_patches, cfg.frontend_dim))
              .astype(np.float32) if cfg.frontend == "patch" else None)
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, prompt_hi))
                                ).astype(np.int32),
            max_new_tokens=max_new, patch_embeds=pe))
    return reqs


def _run(cfg, params, layout, reqs, mb, ms, mesh=None):
    eng = ServingEngine(cfg, params, max_batch=mb, max_seq=ms,
                        page_size=16, layout=layout,
                        mesh=mesh if layout == "paged" else None)
    for r in reqs:
        eng.submit(Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           patch_embeds=r.patch_embeds))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    toks = {r.uid: tuple(r.tokens) for r in results}
    out = dict(tok_s=sum(len(t) for t in toks.values()) / dt,
               peak_kv_bytes=eng.peak_kv_bytes(), tokens=toks,
               shared=eng.pool.stats().shared_pages,
               prefill_shapes=len(eng.prefill_shapes))
    if eng.mesh is not None:
        out["per_shard_peak_pages"] = [
            s["peak_allocated_pages"] for s in eng.pool.shard_stats()]
        out["per_shard_kv_bytes"] = eng.arena.shard_kv_bytes()
    return out


def _row(cfg, params, reqs, mb, ms, oracle_cfg=None, mesh=None):
    """paged side runs `cfg` (possibly --impl/--ppb/--shards overridden);
    the contiguous reference stays on `oracle_cfg` (the default XLA
    impl, single device), so the parity gate is
    fused-kernels/sharded-arena-vs-oracle, never fused-vs-fused."""
    contig = _run(oracle_cfg or cfg, params, "contiguous", reqs, mb, ms)
    paged = _run(cfg, params, "paged", reqs, mb, ms, mesh=mesh)
    same = contig["tokens"] == paged["tokens"]
    row = dict(
        family=cfg.family, batch=mb, max_seq=ms, requests=len(reqs),
        contig_tok_s=contig["tok_s"], paged_tok_s=paged["tok_s"],
        contig_kv_mb=contig["peak_kv_bytes"] / 1e6,
        paged_kv_mb=paged["peak_kv_bytes"] / 1e6,
        kv_ratio=paged["peak_kv_bytes"] / contig["peak_kv_bytes"],
        prefill_shapes=paged["prefill_shapes"],
        tokens_match=same,
        ok=same and paged["peak_kv_bytes"] <= contig["peak_kv_bytes"],
    )
    for k in ("per_shard_peak_pages", "per_shard_kv_bytes"):
        if k in paged:
            row[k] = paged[k]
    return row


def _attention_hlo_stats(cfg) -> dict:
    """Compiled-HLO attention traffic of the jitted paged steps, before
    (XLA oracle formulation: per-layer gathered KV copies) vs after
    (fused Pallas kernels: block-table walk in VMEM).  Bytes come from
    `launch/hlo_analysis` shape accounting over the ACTUAL serving
    closures; the gathered/partials keys are the bulk buffers the
    fusion exists to kill."""
    from repro.launch.hlo_analysis import summarize
    from repro.serve.serve_step import (
        HLO_PROBE_GEOM, bulk_attn_shapes, lowered_paged_hlo)

    bulk_shapes = bulk_attn_shapes(cfg, **HLO_PROBE_GEOM)
    params = registry.get_family(cfg).init(jax.random.key(0), cfg)
    out = {"bulk_attn_shapes": bulk_shapes,
           "backend": jax.default_backend(),
           # off-TPU the flash_pallas steps lower through the Pallas
           # INTERPRETER, whose emulation buffers inflate whole-step
           # totals ~10x — only the bulk_attn_bytes keys are
           # layout-meaningful there; hbm_bytes are backend proxies
           "hbm_bytes_note": ("whole-step totals are backend-lowering "
                              "proxies; off-TPU only bulk_attn_bytes_* "
                              "compare before/after meaningfully")}
    for tag, c in (("before", cfg),
                   ("after", cfg.replace(attention_impl="flash_pallas"))):
        for which in ("decode", "prefill"):
            s = summarize(lowered_paged_hlo(c, which, params=params,
                                            **HLO_PROBE_GEOM))
            bulk = sum(s.bytes_by_shape.get(k, 0.0) for k in bulk_shapes)
            out[f"{which}_bulk_attn_bytes_{tag}"] = bulk
            out[f"{which}_hbm_bytes_{tag}"] = s.hbm_bytes
    return out


def _sampling_sweep(cfg, params, mesh=None) -> dict:
    """Greedy vs per-request-sampled serving on the SAME stream.

    Every request carries its own SamplingParams (temperature ramp,
    top-p nucleus, top-k on odd uids, per-request seed) lowered into
    the jitted step.  PASS requires (a) a seed replay reproduces the
    sampled tokens byte-for-byte (counter-derived randomness — the
    determinism the API guarantees) and (b) the sampled run actually
    diverges from greedy somewhere (the knobs reach the kernel).
    Reported tokens/s tracks the in-step sampling overhead."""
    mb, ms, mnew = 4, 128, 8
    rng = np.random.default_rng(12345)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48)))
               .astype(np.int32) for _ in range(8)]

    def params_for(uid):
        return SamplingParams(temperature=0.7 + 0.05 * uid,
                              top_k=8 if uid % 2 else 0, top_p=0.9,
                              seed=uid, max_new_tokens=mnew)

    def serve(sampled):
        eng = ServingEngine(cfg, params, max_batch=mb, max_seq=ms,
                            page_size=16, mesh=mesh)
        for uid, p in enumerate(prompts):
            eng.submit(Request(
                uid=uid, prompt=p.copy(),
                sampling=params_for(uid) if sampled
                else SamplingParams(max_new_tokens=mnew)))
        t0 = time.perf_counter()
        toks = {r.uid: tuple(r.tokens) for r in eng.run()}
        dt = time.perf_counter() - t0
        return toks, sum(len(t) for t in toks.values()) / dt

    greedy, greedy_tok_s = serve(sampled=False)
    sampled, sampled_tok_s = serve(sampled=True)
    replay, _ = serve(sampled=True)
    deterministic = sampled == replay
    diverged = sampled != greedy
    return dict(mode="per-request temperature + top-p (+ top-k odd uids)",
                requests=len(prompts),
                greedy_tok_s=greedy_tok_s, sampled_tok_s=sampled_tok_s,
                sampled_over_greedy=sampled_tok_s / greedy_tok_s,
                deterministic=deterministic, diverged_from_greedy=diverged,
                ok=deterministic and diverged)


def run(families=None, impl=None, ppb=1, attn_hlo=False,
        shards: int = 1, sampling: bool = False) -> dict:
    families = families or list(FAMILY_CFGS)
    mesh = None
    if shards > 1:
        from repro.launch.mesh import make_mem_mesh
        if jax.device_count() < shards:
            raise SystemExit(
                f"--shards {shards} needs {shards} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={shards})")
        mesh = make_mem_mesh(shards)

    def cfg_of(fam):
        cfg = FAMILY_CFGS[fam]
        if impl:
            cfg = cfg.replace(attention_impl=impl)
        return cfg.replace(attn_pages_per_block=ppb)

    rows, ok = [], True
    # dense batch/seq scaling sweep (covers the dense family point too)
    if "dense" in families:
        cfg = cfg_of("dense")
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        for mb, ms, n, phi, mnew in SWEEP:
            rng = np.random.default_rng(hash((mb, ms)) % 2**32)
            r = _row(cfg, params, _stream(rng, cfg, n, phi, mnew), mb, ms,
                     oracle_cfg=FAMILY_CFGS["dense"], mesh=mesh)
            ok &= r["ok"]
            rows.append(r)
    # family sweep: the rest of the zoo paged-native at one tiny point
    for fam in families:
        if fam == "dense":
            continue
        cfg = cfg_of(fam)
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        # str hash() is salted per process — seed deterministically so
        # the CI smoke workload is reproducible run to run
        rng = np.random.default_rng(1000 + sum(map(ord, fam)))
        p = FAM_POINT
        r = _row(cfg, params, _stream(rng, cfg, p["n"], p["phi"], p["mnew"]),
                 p["mb"], p["ms"], oracle_cfg=FAMILY_CFGS[fam], mesh=mesh)
        ok &= r["ok"]
        rows.append(r)
    result = {"name": "serve_throughput", "schema": SCHEMA, "ok": ok,
              "rows": rows,
              "attention_impl": impl or CFG.attention_impl,
              "pages_per_block": ppb,
              "shard_topology": {"shards": shards,
                                 "mesh_axis": "mem" if mesh is not None
                                 else None,
                                 "devices": jax.device_count(),
                                 "backend": jax.default_backend()}}
    if sampling:
        cfg = cfg_of("dense")
        params = registry.get_family(cfg).init(jax.random.key(0), cfg)
        result["sampling"] = _sampling_sweep(cfg, params, mesh=mesh)
        result["ok"] = ok = ok and result["sampling"]["ok"]
    if attn_hlo:
        result["attention_hlo"] = _attention_hlo_stats(FAMILY_CFGS["dense"])
        # the fused steps must ship ZERO bulk attention bytes
        h = result["attention_hlo"]
        result["ok"] = ok = (ok and h["decode_bulk_attn_bytes_after"] == 0
                             and h["prefill_bulk_attn_bytes_after"] == 0
                             and h["decode_bulk_attn_bytes_before"] > 0
                             and h["prefill_bulk_attn_bytes_before"] > 0)
    return result


def pretty(result: dict):
    print("== Serving: contiguous slots vs UniMem paged arena "
          "(--family sweep: dense,moe,hybrid,vlm) ==")
    topo = result["shard_topology"]
    print(f"   attention_impl={result['attention_impl']} "
          f"pages_per_block={result['pages_per_block']} "
          f"shards={topo['shards']} ({topo['devices']} "
          f"{topo['backend']} devices)")
    print(f"{'family':>8}{'batch':>6}{'max_seq':>8}{'reqs':>6}"
          f"{'contig tok/s':>14}{'paged tok/s':>13}{'contig KV MB':>14}"
          f"{'paged KV MB':>13}{'KV ratio':>10}  tokens")
    for r in result["rows"]:
        shard = ""
        if "per_shard_peak_pages" in r:
            shard = f"  shard peaks {r['per_shard_peak_pages']}"
        print(f"{r['family']:>8}{r['batch']:>6}{r['max_seq']:>8}"
              f"{r['requests']:>6}"
              f"{r['contig_tok_s']:>14.1f}{r['paged_tok_s']:>13.1f}"
              f"{r['contig_kv_mb']:>14.3f}{r['paged_kv_mb']:>13.3f}"
              f"{r['kv_ratio']:>10.2f}  "
              f"{'==' if r['tokens_match'] else 'DIFFER'}{shard}")
    s = result.get("sampling")
    if s:
        print(f"   in-step sampling [{s['mode']}]: greedy "
              f"{s['greedy_tok_s']:.1f} tok/s -> sampled "
              f"{s['sampled_tok_s']:.1f} tok/s "
              f"({s['sampled_over_greedy']:.2f}x); seed replay "
              f"{'identical' if s['deterministic'] else 'DIVERGED'}")
    h = result.get("attention_hlo")
    if h:
        print("   jitted-step attention traffic (compiled HLO, dense): "
              f"decode bulk {h['decode_bulk_attn_bytes_before']/1e3:.0f}kB"
              f" -> {h['decode_bulk_attn_bytes_after']/1e3:.0f}kB, "
              f"prefill bulk {h['prefill_bulk_attn_bytes_before']/1e3:.0f}kB"
              f" -> {h['prefill_bulk_attn_bytes_after']/1e3:.0f}kB")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} "
          "(identical greedy tokens; paged KV high-water <= contiguous "
          "on every family)\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default=",".join(FAMILY_CFGS),
                    help="comma-separated subset of "
                         f"{','.join(FAMILY_CFGS)} to sweep")
    ap.add_argument("--impl", default=None,
                    choices=("dense", "flash_xla", "flash_pallas"),
                    help="attention_impl override (flash_pallas = fused "
                         "paged kernels, interpret mode off-TPU)")
    ap.add_argument("--ppb", type=int, default=1,
                    help="pages per paged-kernel grid cell "
                         "(attn_pages_per_block)")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve the paged side from the near-memory "
                         "SHARDED arena on an N-device 'mem' mesh "
                         "(needs N devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--sampling", action="store_true",
                    help="add the in-step sampling sweep (per-request "
                         "temperature + top-p + seeds on the dense "
                         "stream; gated on seed-replay determinism)")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="write machine-readable results (schema 3: "
                         "tokens/s, peak KV bytes, shard topology, "
                         "sampling-mode sweep, attention HBM bytes "
                         "before/after the kernel fusion) to PATH")
    args = ap.parse_args()
    fams = [f.strip() for f in args.family.split(",") if f.strip()]
    unknown = [f for f in fams if f not in FAMILY_CFGS]
    if unknown:
        raise SystemExit(f"unknown families {unknown}; "
                         f"choose from {list(FAMILY_CFGS)}")
    res = {"name": "serve_throughput", "schema": SCHEMA, "ok": False,
           "error": "run() raised before completing"}
    try:
        res = run(fams, impl=args.impl, ppb=args.ppb,
                  attn_hlo=bool(args.json), shards=args.shards,
                  sampling=args.sampling)
        pretty(res)
    finally:
        # write even when run() raises: the (partial) record is exactly
        # what a failing CI run needs uploaded
        if args.json:
            with open(args.json, "w") as f:
                json.dump(res, f, indent=1, default=str)
            print(f"wrote {args.json}")
    sys.exit(0 if res["ok"] else 1)
