"""Beyond-paper: contiguous vs UniMem-paged serving, measured end-to-end.

Runs the SAME request stream through both engine layouts on a tiny
transformer and reports tokens/s plus peak KV bytes across batch/seq
sweeps.  The paper's claim, serving-shaped: a single pooled page arena
makes KV memory proportional to tokens in flight while the contiguous
layout pins `max_batch * max_seq` regardless of load.  PASS requires
(a) both layouts emit identical greedy tokens and (b) paged peak KV
bytes never exceed contiguous on any sweep point (CPU wall-clock is
reported, not judged — this container is not the serving hardware).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.models.config import ModelConfig
from repro.models import registry
from repro.serve import ServingEngine, Request

CFG = ModelConfig(
    name="bench-dense", family="dense", num_layers=2, d_model=64,
    vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    attn_chunk=32, max_seq=256)

# (max_batch, max_seq, requests, prompt_hi, max_new)
SWEEP = [
    (2, 64, 6, 20, 6),
    (4, 128, 8, 48, 8),
    (4, 256, 8, 96, 8),
]


def _stream(rng, n, prompt_hi, max_new):
    return [Request(uid=i,
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(4, prompt_hi))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run(params, layout, reqs, mb, ms):
    eng = ServingEngine(CFG, params, max_batch=mb, max_seq=ms,
                        page_size=16, layout=layout)
    for r in reqs:
        eng.submit(Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    toks = {r.uid: tuple(r.tokens) for r in results}
    return dict(tok_s=sum(len(t) for t in toks.values()) / dt,
                peak_kv_bytes=eng.peak_kv_bytes(), tokens=toks,
                shared=eng.pool.stats().shared_pages)


def run() -> dict:
    fam = registry.get_family(CFG)
    params = fam.init(jax.random.key(0), CFG)
    rows, ok = [], True
    for mb, ms, n, phi, mnew in SWEEP:
        rng = np.random.default_rng(hash((mb, ms)) % 2**32)
        reqs = _stream(rng, n, phi, mnew)
        contig = _run(params, "contiguous", reqs, mb, ms)
        paged = _run(params, "paged", reqs, mb, ms)
        same = contig["tokens"] == paged["tokens"]
        ok &= same and paged["peak_kv_bytes"] <= contig["peak_kv_bytes"]
        rows.append(dict(
            batch=mb, max_seq=ms, requests=n,
            contig_tok_s=contig["tok_s"], paged_tok_s=paged["tok_s"],
            contig_kv_mb=contig["peak_kv_bytes"] / 1e6,
            paged_kv_mb=paged["peak_kv_bytes"] / 1e6,
            kv_ratio=paged["peak_kv_bytes"] / contig["peak_kv_bytes"],
            tokens_match=same,
        ))
    return {"name": "serve_throughput", "ok": ok, "rows": rows}


def pretty(result: dict):
    print("== Serving: contiguous slots vs UniMem paged arena ==")
    print(f"{'batch':>6}{'max_seq':>8}{'reqs':>6}{'contig tok/s':>14}"
          f"{'paged tok/s':>13}{'contig KV MB':>14}{'paged KV MB':>13}"
          f"{'KV ratio':>10}  tokens")
    for r in result["rows"]:
        print(f"{r['batch']:>6}{r['max_seq']:>8}{r['requests']:>6}"
              f"{r['contig_tok_s']:>14.1f}{r['paged_tok_s']:>13.1f}"
              f"{r['contig_kv_mb']:>14.3f}{r['paged_kv_mb']:>13.3f}"
              f"{r['kv_ratio']:>10.2f}  "
              f"{'==' if r['tokens_match'] else 'DIFFER'}")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} "
          "(identical greedy tokens; paged KV high-water <= contiguous)\n")


if __name__ == "__main__":
    pretty(run())
