"""Beyond-paper: the weight-stationary dataflow on the TPU memory
hierarchy — analytical HBM-traffic sweep (kernel traffic model) plus a
wall-clock sanity run of the Pallas kernels in interpret mode on tiny
shapes (correctness-with-timing, not perf — this container is CPU)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.ws_matmul.kernel import hbm_traffic_model
from repro.kernels.ws_matmul import ops as ws_ops
from repro.kernels.ws_matmul.ref import matmul_ref

# (m, k, n) regimes: decode (tiny m), prefill chunk, train matmul
SWEEP = [
    ("decode b=16", 16 * 8, 8192, 22016),
    ("decode b=128", 128 * 8, 8192, 22016),
    ("prefill chunk", 2048, 8192, 22016),
    ("train mlp", 16 * 4096, 2048, 8192),
]


def run() -> dict:
    rows = []
    for name, m, k, n in SWEEP:
        pad = lambda x, b: -(-x // b) * b
        m2 = pad(m, 128)
        t_full_k = hbm_traffic_model(m2, n, k, bk=min(k, 2048))
        t_small_k = hbm_traffic_model(m2, n, k, bk=128)
        rows.append(dict(
            regime=name, m=m, k=k, n=n,
            ws_GB=t_full_k["weight_stationary"] / 1e9,
            os_GB=t_full_k["output_stationary"] / 1e9,
            ws_small_bk_GB=t_small_k["weight_stationary"] / 1e9,
            winner=("WS" if t_full_k["weight_stationary"]
                    <= t_full_k["output_stationary"] else "OS"),
        ))
    # decode regimes must favor weight-stationary (the paper's point)
    ok = all(r["winner"] == "WS" for r in rows if "decode" in r["regime"])

    # interpret-mode correctness-with-timing on a small shape
    x = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    t0 = time.perf_counter()
    got = ws_ops.ws_matmul(x, w, interpret=True)
    t1 = time.perf_counter()
    ok &= bool(np.allclose(np.asarray(got), np.asarray(matmul_ref(x, w)),
                           rtol=1e-4, atol=1e-4))
    return {"name": "ws_dataflow", "ok": ok, "rows": rows,
            "interpret_ms": (t1 - t0) * 1e3}


def pretty(result: dict):
    print("== Weight-stationary vs output-stationary HBM traffic "
          "(TPU adaptation of the paper's dataflow) ==")
    print(f"{'regime':<16}{'m':>8}{'k':>7}{'n':>7}{'WS GB':>9}{'OS GB':>9}"
          f"{'WS bk=128':>11}  winner")
    for r in result["rows"]:
        print(f"{r['regime']:<16}{r['m']:>8}{r['k']:>7}{r['n']:>7}"
              f"{r['ws_GB']:>9.2f}{r['os_GB']:>9.2f}"
              f"{r['ws_small_bk_GB']:>11.2f}  {r['winner']}")
    print(f"interpret-mode kernel check: {result['interpret_ms']:.0f} ms")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} "
          "(WS wins the paper's decode regime)\n")


if __name__ == "__main__":
    pretty(run())
