"""Trace-driven traffic generator for the network serving front.

Drives the REAL `FrontendServer` (serve/frontend) over localhost TCP —
HTTP submit, SSE streaming, client aborts — with a seeded synthetic
trace that mixes the arrival patterns a production engine must survive:

* Poisson chatbot arrivals with heavy-tailed prompt/output lengths
  (Pareto — most requests are small, the tail is not),
* a BURST STORM: a knot of simultaneous arrivals mid-trace,
* one GIANT PROMPT amid the chatbots (the head-of-line-blocking bait
  the chunked-prefill scheduler exists to defuse),
* fork FANOUT requests (one prompt, several sampling regimes over one
  socket via the engine's COW fork),
* mid-flight CLIENT ABORTS (socket drop, no cancel frame — the
  disconnect path must reclaim pages).

Requests carry two tenants ("alpha" weight 3, "beta" weight 1); the
engine runs with `tenant_weights` so admission order and the token
budget follow weighted max-min shares (frontend/tenants.py).  The
giant prompt is submitted under BETA — fairness should keep alpha's
latency tail intact while beta absorbs its own whale.

Reported per tenant: TTFT and TPOT p50/p99 (wall-clock, measured at the
client), goodput under a TTFT SLO (completed tokens/s counting only
SLO-meeting streams), plus engine admission/preemption/cancellation
counters and the cancel-reclaim latency (abort -> pages back in the
pool, measured by polling GET /v1/stats).

PASS gates (CPU-safe — wall-clock magnitudes are reported, not judged):
  (a) every accepted, non-aborted stream receives its finish frame;
  (b) a token-identity subset: streams replayed in-process through
      `LLMServer` with the same params are byte-identical to what
      crossed the wire;
  (c) p99 TTFT is finite under the burst (every stream actually
      started — no starved tenant);
  (d) zero leaked pages after the trace drains (allocated == pinned,
      no open routes).

    PYTHONPATH=src python benchmarks/traffic_gen.py \
        [--requests 24] [--horizon 1.5] [--shards 8] [--seed 0] \
        [--json BENCH_traffic.json]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time

import numpy as np

from repro.models.config import ModelConfig
from repro.serve.api import LLMServer
from repro.serve.frontend import FrontendServer, ServeClient
from repro.serve.sampling import SamplingParams

# machine-readable result schema: 1 = per-tenant TTFT/TPOT p50/p99,
# goodput-under-SLO, cancel-reclaim latency, admission/preemption/
# cancellation counters, gate booleans
SCHEMA = 1

CFG = ModelConfig(
    name="traffic-dense", family="dense", num_layers=2, d_model=64,
    vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    attn_chunk=32, max_seq=256)

TENANT_WEIGHTS = {"alpha": 3.0, "beta": 1.0}
TTFT_SLO_S = 5.0          # generous: CPU jit warmup dominates the first tick


# ------------------------------------------------------------------- trace

def build_trace(rng: np.random.Generator, n: int, horizon: float,
                max_seq: int) -> list[dict]:
    """Seeded synthetic trace: a list of submit descriptions, each
    {"at": arrival_s, "tenant", "prompt", "params", "fanout",
    "abort_after": tokens | None}.  Arrivals are Poisson over
    [0, horizon) with a burst storm knotted at horizon/2 and one giant
    prompt; lengths are Pareto (heavy-tailed)."""
    def lengths():
        plen = int(min(max_seq // 4, 4 + rng.pareto(1.5) * 6))
        gen = int(min(24, 3 + rng.pareto(1.3) * 4))
        return max(2, plen), max(2, gen)

    def prompt(plen):
        return rng.integers(1, CFG.vocab_size, size=plen).tolist()

    entries: list[dict] = []
    # Poisson chatbots (~60% of n)
    t = 0.0
    n_chat = max(4, int(n * 0.6))
    for _ in range(n_chat):
        t += rng.exponential(horizon / max(n_chat, 1))
        plen, gen = lengths()
        entries.append(dict(
            at=min(t, horizon), tenant=("alpha" if rng.random() < 0.6
                                        else "beta"),
            prompt=prompt(plen),
            params=SamplingParams(max_new_tokens=gen,
                                  temperature=float(rng.choice([0.0, 0.8])),
                                  top_k=20, seed=int(rng.integers(1 << 20))),
            fanout=None, abort_after=None))
    # burst storm: simultaneous knot at horizon/2 (~25% of n)
    for _ in range(max(3, int(n * 0.25))):
        plen, gen = lengths()
        entries.append(dict(
            at=horizon / 2 + float(rng.random()) * 1e-3,
            tenant=("alpha" if rng.random() < 0.5 else "beta"),
            prompt=prompt(plen),
            params=SamplingParams(max_new_tokens=gen,
                                  seed=int(rng.integers(1 << 20))),
            fanout=None, abort_after=None))
    # one giant prompt (under beta — fairness should shield alpha)
    entries.append(dict(
        at=horizon * 0.4, tenant="beta",
        prompt=prompt(max_seq // 2),
        params=SamplingParams(max_new_tokens=8),
        fanout=None, abort_after=None))
    # fork fanout: one prompt, two extra sampling regimes
    plen, gen = lengths()
    entries.append(dict(
        at=horizon * 0.3, tenant="alpha", prompt=prompt(plen),
        params=SamplingParams(max_new_tokens=max(4, gen), seed=11),
        fanout=[SamplingParams(max_new_tokens=max(4, gen), seed=12,
                               temperature=0.9),
                SamplingParams(max_new_tokens=max(4, gen), seed=13,
                               temperature=0.9, top_p=0.8)],
        abort_after=None))
    # mid-flight aborts: two long streams dropped at their 3rd token
    for frac in (0.25, 0.6):
        entries.append(dict(
            at=horizon * frac, tenant="beta",
            prompt=prompt(8),
            params=SamplingParams(max_new_tokens=40),
            fanout=None, abort_after=3))
    entries.sort(key=lambda e: e["at"])
    return entries


# ----------------------------------------------------------------- drivers

async def _drive_one(client: ServeClient, entry: dict, t_start: float
                     ) -> dict:
    """Submit one trace entry at its arrival time; stream to completion
    (or abort); return wall-clock observations."""
    await asyncio.sleep(max(0.0, entry["at"] - (time.perf_counter()
                                                - t_start)))
    obs = dict(tenant=entry["tenant"], submitted_at=time.perf_counter(),
               ttft=None, token_times=[], finished={}, aborted=False,
               tokens={}, error=None, prompt=entry["prompt"],
               params=entry["params"], abort_after=entry["abort_after"])
    try:
        stream = await client.submit(entry["prompt"], entry["params"],
                                     tenant=entry["tenant"],
                                     fanout=entry["fanout"])
    except Exception as e:                        # rejected at admission
        obs["error"] = str(e)
        return obs
    n_sid0 = 0
    async for event, data in stream:
        now = time.perf_counter()
        sid = data.get("sid")
        if event == "token":
            obs["tokens"].setdefault(sid, []).append(data["t"])
            if sid == 0:
                if obs["ttft"] is None:
                    obs["ttft"] = now - obs["submitted_at"]
                obs["token_times"].append(now)
                n_sid0 += 1
                if (entry["abort_after"] is not None
                        and n_sid0 >= entry["abort_after"]):
                    obs["aborted"] = True
                    obs["abort_at"] = now
                    await stream.abort()
                    break
        elif event == "finish":
            obs["finished"][sid] = data["reason"]
        elif event == "error":
            obs["error"] = f"{data.get('code')}: {data.get('message')}"
    return obs


async def _cancel_reclaim_latency(client: ServeClient, obs_aborts: list
                                  ) -> float:
    """Poll /v1/stats until every abort's pages are back (allocated ==
    pinned and the cancellation counter covers them); returns seconds
    from the LAST abort to reclaim."""
    if not obs_aborts:
        return 0.0
    t_abort = max(o["abort_at"] for o in obs_aborts)
    deadline = time.perf_counter() + 30.0
    while time.perf_counter() < deadline:
        s = await client.stats()
        eng = s["engine"]
        pool = eng.get("pool", {})
        if (eng.get("cancellations", 0) >= len(obs_aborts)
                and pool.get("allocated_pages", -1)
                == pool.get("pinned_pages", 0)):
            return time.perf_counter() - t_abort
        await asyncio.sleep(0.005)
    return float("inf")


async def _run_trace(port: int, entries: list[dict]) -> tuple[list, float]:
    client = ServeClient("127.0.0.1", port)
    t_start = time.perf_counter()
    obs = await asyncio.gather(*[_drive_one(client, e, t_start)
                                 for e in entries])
    reclaim = await _cancel_reclaim_latency(
        client, [o for o in obs if o["aborted"]])
    return list(obs), reclaim


# ----------------------------------------------------------------- metrics

def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if xs else None


def _tenant_rows(obs: list[dict], wall: float) -> list[dict]:
    rows = []
    for tenant in sorted(TENANT_WEIGHTS):
        mine = [o for o in obs if o["tenant"] == tenant
                and o["error"] is None]
        ttfts = [o["ttft"] for o in mine if o["ttft"] is not None]
        tpots = []
        for o in mine:
            ts = o["token_times"]
            if len(ts) >= 2:
                tpots.append((ts[-1] - ts[0]) / (len(ts) - 1))
        done = [o for o in mine if not o["aborted"] and 0 in o["finished"]]
        slo_ok = [o for o in done if o["ttft"] is not None
                  and o["ttft"] <= TTFT_SLO_S]
        slo_tokens = sum(len(toks) for o in slo_ok
                         for toks in o["tokens"].values())
        rows.append(dict(
            tenant=tenant, weight=TENANT_WEIGHTS[tenant],
            requests=len(mine), completed=len(done),
            aborted=sum(o["aborted"] for o in mine),
            ttft_p50_s=_pct(ttfts, 50), ttft_p99_s=_pct(ttfts, 99),
            tpot_p50_s=_pct(tpots, 50), tpot_p99_s=_pct(tpots, 99),
            slo_attainment=(len(slo_ok) / len(done)) if done else None,
            goodput_tok_s=slo_tokens / wall if wall > 0 else 0.0))
    return rows


def _token_identity(frontend: FrontendServer, obs: list[dict],
                    max_checks: int = 3) -> tuple[bool, int]:
    """Replay a subset of completed streams in-process with the SAME
    model params; over-the-wire tokens must be byte-identical."""
    llm = LLMServer(CFG, frontend.llm.engine.params, max_batch=4,
                    max_seq=CFG.max_seq)
    checked, ok = 0, True
    for o in obs:
        if checked >= max_checks:
            break
        if o["aborted"] or o["error"] is not None or 0 not in o["finished"]:
            continue
        res = llm.generate(o["prompt"], o["params"]).drain()
        ok &= (o["tokens"].get(0, []) == list(res.tokens))
        checked += 1
    return ok, checked


# --------------------------------------------------------------------- run

def run(requests: int = 24, horizon: float = 1.5, shards: int | None = None,
        seed: int = 0, json_path: str | None = "BENCH_traffic.json") -> dict:
    mesh = None
    if shards:
        from repro.launch.mesh import make_mem_mesh
        mesh = make_mem_mesh(shards)
    rng = np.random.default_rng(seed)
    entries = build_trace(rng, requests, horizon, CFG.max_seq)

    srv = FrontendServer(CFG, host="127.0.0.1", port=0,
                         max_batch=4, max_seq=CFG.max_seq, page_size=16,
                         tick_token_budget=64, mesh=mesh,
                         tenant_weights=TENANT_WEIGHTS)
    srv.start()
    t0 = time.perf_counter()
    try:
        obs, reclaim_s = asyncio.run(_run_trace(srv.port, entries))
        wall = time.perf_counter() - t0
        stats = srv.llm.stats
        fe = dict(srv.counters)
    finally:
        srv.stop()

    rows = _tenant_rows(obs, wall)
    accepted = [o for o in obs if o["error"] is None]
    # (a) every accepted, non-aborted stream finished — including every
    # fanout child sid it was promised
    ok_complete = all(
        o["aborted"] or (0 in o["finished"]
                         and len(o["finished"]) == len(o["tokens"]))
        for o in accepted)
    # (b) byte-identity with in-process serving
    ok_identity, n_checked = _token_identity(srv, obs)
    # (c) p99 TTFT finite: every accepted stream actually started
    ok_ttft = all(o["ttft"] is not None for o in accepted) and all(
        r["ttft_p99_s"] is not None and np.isfinite(r["ttft_p99_s"])
        for r in rows if r["requests"])
    # (d) zero leaked pages once drained
    pool = stats.get("pool", {})
    ok_leak = bool(pool.get("allocated_pages", -1)
                   == pool.get("pinned_pages", 0)
                   and np.isfinite(reclaim_s))

    result = {
        "name": "traffic_gen", "schema": SCHEMA,
        "ok": bool(ok_complete and ok_identity and ok_ttft and ok_leak),
        "gates": dict(streams_complete=ok_complete,
                      token_identity=ok_identity,
                      identity_checked=n_checked,
                      ttft_finite=ok_ttft, zero_leaked_pages=ok_leak),
        "rows": rows,
        "trace": dict(requests=len(entries), horizon_s=horizon, seed=seed,
                      wall_s=wall, shards=shards or 1),
        "cancel_reclaim_s": reclaim_s,
        "counters": dict(admitted=stats.get("admitted"),
                         preemptions=stats.get("preemptions"),
                         cancellations=stats.get("cancellations"),
                         frontend=fe),
        "tenant_tokens": {t: v["tokens"]
                          for t, v in stats.get("tenants", {}).items()},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def pretty(result: dict):
    print(f"== traffic_gen (network front, schema {result['schema']}) ==")
    tr = result["trace"]
    print(f"  trace: {tr['requests']} requests over {tr['horizon_s']}s "
          f"(seed {tr['seed']}, {tr['shards']} shard(s)), "
          f"drained in {tr['wall_s']:.2f}s")
    hdr = (f"  {'tenant':<8} {'w':>3} {'req':>4} {'done':>5} {'abrt':>5} "
           f"{'ttft p50':>9} {'ttft p99':>9} {'tpot p50':>9} "
           f"{'slo%':>6} {'goodput':>9}")
    print(hdr)
    for r in result["rows"]:
        def fmt(x, unit=""):
            return "-" if x is None else f"{x:.3f}{unit}"
        slo = ("-" if r["slo_attainment"] is None
               else f"{100 * r['slo_attainment']:.0f}%")
        print(f"  {r['tenant']:<8} {r['weight']:>3.0f} {r['requests']:>4} "
              f"{r['completed']:>5} {r['aborted']:>5} "
              f"{fmt(r['ttft_p50_s'], 's'):>9} {fmt(r['ttft_p99_s'], 's'):>9} "
              f"{fmt(r['tpot_p50_s'], 's'):>9} "
              f"{slo:>6} {r['goodput_tok_s']:>7.1f}/s")
    c = result["counters"]
    print(f"  cancel-reclaim {result['cancel_reclaim_s'] * 1e3:.0f} ms | "
          f"admitted {c['admitted']} preemptions {c['preemptions']} "
          f"cancellations {c['cancellations']}")
    print(f"  gates: {result['gates']}")
    print(f"  -> {'PASS' if result['ok'] else 'FAIL'}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--horizon", type=float, default=1.5)
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_traffic.json")
    a = ap.parse_args()
    res = run(requests=a.requests, horizon=a.horizon, shards=a.shards,
              seed=a.seed, json_path=a.json)
    pretty(res)
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
