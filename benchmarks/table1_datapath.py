"""Paper Table I: Interposer vs TSV vs HITOC data-path comparison."""
from __future__ import annotations

from repro.core import datapath as DP


def run() -> dict:
    rows, ok = [], True
    for tech in (DP.INTERPOSER, DP.TSV, DP.HITOC):
        rep = DP.report(tech)
        want = DP.PAPER_TABLE1[tech.name]
        d_density = rep.wire_density / want["density"] - 1
        d_bw = rep.bandwidth_TBps / want["bandwidth_TBps"] - 1
        ok &= abs(d_density) < 0.05 and abs(d_bw) < 0.05
        rows.append(dict(
            tech=tech.name, pitch_um=tech.pitch_um,
            density=rep.wire_density, density_paper=want["density"],
            bw_TBps=rep.bandwidth_TBps, bw_paper=want["bandwidth_TBps"],
            pJ_per_bit=rep.energy_pj_per_bit,
            watts_at_full_bw=rep.power_w_at_bw,
        ))
    return {"name": "table1_datapath", "ok": ok, "rows": rows}


def pretty(result: dict):
    print("== Table I: data-path comparison (computed vs paper) ==")
    hdr = f"{'tech':<11}{'pitch um':>9}{'wires/mm^2':>13}{'paper':>11}" \
          f"{'TB/s':>9}{'paper':>7}{'pJ/b':>7}{'W@BW':>8}"
    print(hdr)
    for r in result["rows"]:
        print(f"{r['tech']:<11}{r['pitch_um']:>9.1f}{r['density']:>13.3g}"
              f"{r['density_paper']:>11.3g}{r['bw_TBps']:>9.3g}"
              f"{r['bw_paper']:>7.3g}{r['pJ_per_bit']:>7.2f}"
              f"{r['watts_at_full_bw']:>8.2f}")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} (within 5% of paper)\n")


if __name__ == "__main__":
    pretty(run())
