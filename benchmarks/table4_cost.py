"""Paper Table IV: NRE / die cost / cost-per-TOPS from first principles."""
from __future__ import annotations

from repro.core import hwmodel as HW


def run() -> dict:
    rows, ok = [], True
    for rep in HW.table4():
        nre, die, cpt = HW.PAPER_TABLE4[rep.name]
        ratio = rep.die_cost_usd / die
        ok &= rep.nre_usd == nre and 0.4 < ratio < 2.5
        rows.append(dict(
            chip=rep.name, nre_usd=rep.nre_usd, nre_paper=nre,
            gross_dies=rep.gross_dies, yield_frac=rep.yield_frac,
            die_cost=rep.die_cost_usd, die_cost_paper=die,
            cost_per_tops=rep.cost_per_tops, cpt_paper=cpt,
        ))
    best = min(rows, key=lambda r: r["cost_per_tops"])
    ok &= best["chip"] == "Sunrise"   # the paper's headline cost claim
    return {"name": "table4_cost", "ok": ok, "rows": rows}


def pretty(result: dict):
    print("== Table IV: cost comparison (computed | paper) ==")
    print(f"{'chip':<10}{'NRE $M':>8}{'gross':>7}{'yield':>7}"
          f"{'die $':>16}{'$/TOPS':>16}")
    for r in result["rows"]:
        print(f"{r['chip']:<10}{r['nre_usd'] / 1e6:>8.1f}"
              f"{r['gross_dies']:>7.0f}{r['yield_frac']:>7.2f}"
              f"{r['die_cost']:>8.0f}|{r['die_cost_paper']:<7.0f}"
              f"{r['cost_per_tops']:>8.2f}|{r['cpt_paper']:<7.2f}")
    print(f"-> {'PASS' if result['ok'] else 'FAIL'} "
          "(NRE exact, die cost within publication tolerance, "
          "Sunrise best $/TOPS)\n")


if __name__ == "__main__":
    pretty(run())
